"""Scheduling-policy seam: chunked prefill parity, priority/deadline
admission order, and preemption round-trips.

All configs lift the MoE capacity bound (capacity_factor=64) so batch
composition cannot perturb outputs — every comparison here is exact
token-for-token (see docs/serving.md on capacity-dropped MoE determinism).
"""

import time
from collections import deque

import jax
import numpy as np
import pytest

from repro.configs.base import OneRecConfig, TransformerConfig
from repro.models import onerec as onerec_model
from repro.serving import (ContinuousScheduler, EngineConfig, PhaseExecutor,
                           PrefixStore, Request, SchedulingPolicy,
                           ServingEngine, SlotPool)


def _cfg() -> OneRecConfig:
    return OneRecConfig(
        name="onerec-sched-test",
        history_len=16,
        transformer=TransformerConfig(
            name="onerec-sched-test-backbone",
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
            d_ff=128, vocab_size=256, moe=True, n_experts=4, top_k=2,
            d_expert=64, capacity_factor=64.0, ep_degree=4,
            max_seq_len=64, remat=False),
        serve_batch=4, beam_width=4)


def _request_dicts(cfg, n, rng, min_items=2, force_full=2):
    """Mixed-length histories; the last ``force_full`` use the full
    context, so chunked prefill always has multi-segment work."""
    reqs = []
    for i in range(n):
        n_items = cfg.history_len if i >= n - force_full else \
            int(rng.integers(min_items, cfg.history_len + 1))
        reqs.append({
            "tokens": rng.integers(0, 192, size=n_items * cfg.n_codebooks
                                   ).astype(np.int32),
            "profile": rng.normal(size=onerec_model.PROFILE_DIM
                                  ).astype(np.float32)})
    return reqs


@pytest.fixture(scope="module")
def sched_setup():
    cfg = _cfg()
    params = onerec_model.init_onerec(jax.random.PRNGKey(0), cfg)
    reqs = _request_dicts(cfg, 9, np.random.default_rng(11))
    return cfg, params, reqs


# ---------------------------------------------------------------------------
# Chunked prefill
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_chunked_matches_monolithic(sched_setup):
    """Paging a prefill through engine steps must not change a single
    token — resume segments write the same K/V at the same positions."""
    cfg, params, reqs = sched_setup
    out_m, st_m = ServingEngine(params, cfg, EngineConfig(
        batch_size=4, mode="continuous")).serve_requests(reqs)
    out_c, st_c = ServingEngine(params, cfg, EngineConfig(
        batch_size=4, mode="continuous",
        prefill_chunk=8)).serve_requests(reqs)
    for a, b in zip(out_c, out_m):
        np.testing.assert_array_equal(a, b)
    # chunking trades one big program for several bounded ones
    assert st_c["prefill_calls"] > st_m["prefill_calls"]
    assert st_c["join_steps"] > 0 and st_m["join_p99_s"] > 0


@pytest.mark.slow
def test_chunked_with_prefix_cache_parity(sched_setup):
    """Chunked suffix prefill composes with tier-2 prefix reuse: repeat
    traffic through a chunked+cached engine stays token-identical."""
    cfg, params, reqs = sched_setup
    out_ref, _ = ServingEngine(params, cfg, EngineConfig(
        batch_size=4, mode="continuous")).serve_requests(reqs)
    eng = ServingEngine(params, cfg, EngineConfig(
        batch_size=4, mode="continuous", prefill_chunk=8,
        prefix_cache=True))
    out_cold, _ = eng.serve_requests(reqs)       # misses, chunked
    out_warm, stats = eng.serve_requests(reqs)   # hits + short suffixes
    for a, b, c in zip(out_cold, out_warm, out_ref):
        np.testing.assert_array_equal(a, c)
        np.testing.assert_array_equal(b, c)
    assert stats["prefix_hit_rate"] > 0


def test_fixed_mode_rejects_policy_knobs(sched_setup):
    cfg, params, _ = sched_setup
    with pytest.raises(ValueError):
        ServingEngine(params, cfg, EngineConfig(mode="fixed",
                                                prefill_chunk=8))
    with pytest.raises(ValueError):
        ServingEngine(params, cfg, EngineConfig(mode="fixed",
                                                preemption=True))


# ---------------------------------------------------------------------------
# Priority / deadline admission
# ---------------------------------------------------------------------------


def test_priority_admission_order(sched_setup):
    """With one slot, a later-queued higher-priority request is served
    first: its latency must undercut both lower-class requests'."""
    cfg, params, reqs = sched_setup
    staged = [dict(reqs[0], priority=1), dict(reqs[1], priority=1),
              dict(reqs[2], priority=0)]
    eng = ServingEngine(params, cfg, EngineConfig(
        batch_size=1, n_slots=1, mode="continuous"))
    eng.serve_requests(staged)                   # compile warmup
    eng.serve_requests(staged)
    lat = eng.metrics["latency_s"]
    assert lat[2] < lat[0] and lat[2] < lat[1]


def test_deadline_orders_within_class(sched_setup):
    """Equal classes: earliest deadline first."""
    cfg, params, reqs = sched_setup
    staged = [dict(reqs[0], deadline_s=50.0), dict(reqs[1], deadline_s=0.5)]
    eng = ServingEngine(params, cfg, EngineConfig(
        batch_size=1, n_slots=1, mode="continuous"))
    eng.serve_requests(staged)                   # compile warmup
    eng.serve_requests(staged)
    lat = eng.metrics["latency_s"]
    assert lat[1] < lat[0]


def test_deadline_miss_accounting(sched_setup):
    """Misses are counted against requests WITH deadlines, per class."""
    cfg, params, reqs = sched_setup
    staged = [dict(reqs[0], deadline_s=-0.001),   # already past at t0
              dict(reqs[1], deadline_s=1000.0),
              dict(reqs[2])]                      # no SLA
    _, stats = ServingEngine(params, cfg, EngineConfig(
        batch_size=4, mode="continuous")).serve_requests(staged)
    assert stats["deadline_misses"] == 1.0
    assert stats["deadline_miss_rate"] == pytest.approx(0.5)
    assert stats["class_stats"]["0"]["n"] == 3.0
    assert stats["class_stats"]["0"]["deadline_misses"] == 1.0


# ---------------------------------------------------------------------------
# Preemption
# ---------------------------------------------------------------------------


def _mk_request(rid, req, priority=0, arrival_s=0.0):
    return Request(rid=rid, tokens=np.asarray(req["tokens"], np.int32),
                   profile=np.asarray(req["profile"], np.float32),
                   arrival_s=arrival_s, priority=priority)


def _drain(sched, queue, done):
    while queue or sched.pool.n_used:
        sched._advance_prefills(done)
        sched._join(queue, done)
        if sched._decoding_slots():
            sched._decode_step(done)


@pytest.mark.slow
def test_preemption_roundtrip_parity(sched_setup):
    """Preempt mid-decode -> requeue -> outputs token-identical to an
    unpreempted run, with the resume riding the prefix store (row copy +
    suffix prefill, not a full re-prefill)."""
    cfg, params, reqs = sched_setup
    # reference: same requests, pool big enough that nothing competes
    ref_out, _ = ServingEngine(params, cfg, EngineConfig(
        batch_size=8, n_slots=8, mode="continuous")).serve_requests(
        [dict(r) for r in reqs[:3]])

    ex = PhaseExecutor(params, cfg, n_slots=2, use_fp8=True, prefix_rows=4)
    store = PrefixStore(4, ex.arena_row_bytes, n_codebooks=cfg.n_codebooks)
    pool = SlotPool(2)
    sched = ContinuousScheduler(ex, pool, prefix_store=store,
                                policy=SchedulingPolicy(preemption=True))
    low = [_mk_request(0, reqs[0], priority=1),
           _mk_request(1, reqs[1], priority=1)]
    high = _mk_request(2, reqs[2], priority=0)

    queue, done = deque(low), []
    sched._join(queue, done)                 # both lows admitted
    assert pool.n_used == 2 and not queue
    sched._decode_step(done)                 # mid-decode (decode_len=3)
    assert not done
    queue.append(high)
    sched._join(queue, done)                 # preempts one low for high
    assert sched.preemptions == 1
    assert pool.n_used == 2 and len(queue) == 1
    resumes_before = ex.counters["resume_calls"]
    _drain(sched, queue, done)

    assert len(done) == 3
    by_rid = {c.rid: c for c in done}
    for rid in range(3):
        np.testing.assert_array_equal(by_rid[rid].item, ref_out[rid])
    # the preempted request came back through the arena, not a re-prefill
    assert ex.counters["resume_calls"] > resumes_before
    assert store.hits >= 1


def test_preemption_requires_strictly_worse_victim(sched_setup):
    """An equal-or-better class never gets preempted: the arrival waits."""
    cfg, params, reqs = sched_setup
    ex = PhaseExecutor(params, cfg, n_slots=1, use_fp8=True)
    pool = SlotPool(1)
    sched = ContinuousScheduler(ex, pool,
                                policy=SchedulingPolicy(preemption=True))
    first = _mk_request(0, reqs[0], priority=0)
    rival = _mk_request(1, reqs[1], priority=0)
    queue, done = deque([first]), []
    sched._join(queue, done)
    sched._decode_step(done)
    queue.append(rival)
    sched._join(queue, done)                 # no free slot, equal class
    assert sched.preemptions == 0
    assert pool[0].request_id == 0           # incumbent kept its slot
    _drain(sched, queue, done)
    assert len(done) == 2


def test_preemption_latency_spans_requeue(sched_setup):
    """A preempted request's latency runs from its ORIGINAL arrival."""
    cfg, params, reqs = sched_setup
    ex = PhaseExecutor(params, cfg, n_slots=1, use_fp8=True, prefix_rows=2)
    store = PrefixStore(2, ex.arena_row_bytes, n_codebooks=cfg.n_codebooks)
    pool = SlotPool(1)
    sched = ContinuousScheduler(ex, pool, prefix_store=store,
                                policy=SchedulingPolicy(preemption=True))
    t_arr = time.perf_counter()
    low = _mk_request(0, reqs[0], priority=1, arrival_s=t_arr)
    high = _mk_request(1, reqs[1], priority=0, arrival_s=t_arr)
    queue, done = deque([low]), []
    sched._join(queue, done)
    sched._decode_step(done)
    queue.append(high)
    sched._join(queue, done)
    assert sched.preemptions == 1
    _drain(sched, queue, done)
    by_rid = {c.rid: c for c in done}
    # the preempted request waited for the high one: it finished last and
    # its latency covers both service attempts
    assert by_rid[0].latency_s > by_rid[1].latency_s
