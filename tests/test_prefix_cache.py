"""Two-tier KV cache: prefix-store hashing/refcount/eviction invariants,
resume-prefill parity with full prefill, and engine-level cache-on/off
token equality."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import hypothesis, st

from repro.configs.base import OneRecConfig, TransformerConfig
from repro.layers.attention import AttnSpec, apply_attention, init_attention, \
    init_cache
from repro.models import onerec as onerec_model
from repro.serving import (EngineConfig, PrefixStore, ServingEngine,
                           prefix_hash_chain)
from repro.serving.executor import PhaseExecutor

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=40,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("ci")

NCB = 3  # codebooks per item


def _prof(seed=0):
    return np.random.default_rng(seed).normal(size=8).astype(np.float32)


def _toks(n_items, seed=0):
    return np.random.default_rng(seed).integers(
        0, 100, size=n_items * NCB).astype(np.int32)


# ---------------------------------------------------------------------------
# Hash chain
# ---------------------------------------------------------------------------


def test_hash_chain_stability_and_boundaries():
    """Equal content -> equal digests (across calls); one digest per FULL
    item; a longer history's chain extends the shorter's unchanged."""
    prof, toks = _prof(), _toks(4)
    a = list(prefix_hash_chain(prof, toks, NCB))
    b = list(prefix_hash_chain(prof.copy(), toks.copy(), NCB))
    assert a == b
    assert [n for n, _ in a] == [3, 6, 9, 12]
    # partial trailing item is not a boundary
    c = list(prefix_hash_chain(prof, np.concatenate([toks, toks[:1]]), NCB))
    assert c == a
    # prefix property: extending the history never rewrites earlier digests
    other = list(prefix_hash_chain(prof, _toks(6, seed=5), NCB))
    assert [d for _, d in other[:4]] != [d for _, d in a]  # distinct content
    ext = list(prefix_hash_chain(
        prof, np.concatenate([toks, _toks(2, seed=9)]), NCB))
    assert ext[:4] == a


def test_hash_chain_discriminates_profile_and_tokens():
    toks = _toks(3)
    base = list(prefix_hash_chain(_prof(0), toks, NCB))
    other_prof = list(prefix_hash_chain(_prof(1), toks, NCB))
    assert [d for _, d in base] != [d for _, d in other_prof]
    bent = toks.copy()
    bent[0] += 1
    other_tok = list(prefix_hash_chain(_prof(0), bent, NCB))
    assert base[0][1] != other_tok[0][1]


# ---------------------------------------------------------------------------
# Store: refcounts, LRU eviction, byte budget
# ---------------------------------------------------------------------------


def test_store_insert_lookup_roundtrip():
    store = PrefixStore(n_rows=4, row_bytes=100, n_codebooks=NCB)
    prof, toks = _prof(), _toks(4)
    entry = store.insert(prof, toks, 12)
    assert entry is not None and 0 <= entry.row < 4
    hit = store.lookup_longest(prof, toks)
    assert hit is not None and hit[0] is entry and hit[1] == 12
    # boundary index: shorter prefixes of the same content hit the same row
    hit = store.lookup_longest(prof, toks, max_tokens=11)
    assert hit is not None and hit[0] is entry and hit[1] == 9
    # exact-duplicate insert dedups
    assert store.insert(prof, toks, 12) is None
    assert store.n_entries == 1


def test_store_pinned_rows_never_evicted():
    store = PrefixStore(n_rows=2, row_bytes=100, n_codebooks=NCB)
    e0 = store.insert(_prof(0), _toks(2, seed=0), 6)
    e1 = store.insert(_prof(1), _toks(2, seed=1), 6)
    store.acquire(e0)
    store.acquire(e1)
    # full + everything pinned: insert must fail, not steal a row
    assert store.insert(_prof(2), _toks(2, seed=2), 6) is None
    store.release(e0)
    e2 = store.insert(_prof(2), _toks(2, seed=2), 6)
    assert e2 is not None and e2.row == e0.row       # LRU unpinned evicted
    assert store.lookup_longest(_prof(0), _toks(2, seed=0)) is None
    assert store.evictions == 1
    with pytest.raises(ValueError):
        store.release(e0)                            # already unpinned


def test_store_eviction_keeps_shared_boundaries_alive():
    """Evicting an entry must not orphan boundary digests a surviving
    entry (sharing a content prefix) still covers; and content already
    covered by a longer entry's boundary dedups instead of burning a row."""
    toks = _toks(4)                      # items ABCD
    short, prof = toks[:2 * NCB], _prof()

    # dedup: content covered by a LONGER entry's boundary burns no row
    store = PrefixStore(n_rows=2, row_bytes=100, n_codebooks=NCB)
    assert store.insert(prof, toks, 4 * NCB) is not None      # ABCD
    assert store.insert(prof, short, 2 * NCB) is None         # AB covered
    assert store.n_entries == 1

    # orphan re-claim: evict the OWNER of shared digests (AB, the LRU);
    # the surviving ABCD row must keep serving the shared boundaries
    store = PrefixStore(n_rows=2, row_bytes=100, n_codebooks=NCB)
    assert store.insert(prof, short, 2 * NCB) is not None     # AB owns d1,d2
    assert store.insert(prof, toks, 4 * NCB) is not None      # ABCD: d3,d4
    assert store.insert(_prof(5), _toks(2, seed=5), 2 * NCB) is not None
    assert store.evictions == 1                               # AB evicted
    hit = store.lookup_longest(prof, short)   # AB served by ABCD's row
    assert hit is not None and hit[1] == 2 * NCB
    assert hit[0].n_tokens == 4 * NCB


def test_store_is_live_tracks_same_batch_eviction():
    """A second insert in one save batch can evict the first (full store,
    nothing else unpinned); ``is_live`` is how the scheduler drops the
    dead entry's pending row copy."""
    store = PrefixStore(n_rows=1, row_bytes=10, n_codebooks=NCB)
    a = store.insert(_prof(0), _toks(2, seed=0), 6)
    b = store.insert(_prof(1), _toks(2, seed=1), 6)
    assert a is not None and b is not None and a.row == b.row
    assert not store.is_live(a) and store.is_live(b)


def test_store_byte_budget_caps_rows():
    store = PrefixStore(n_rows=4, row_bytes=100, max_bytes=250,
                        n_codebooks=NCB)
    for s in range(3):
        store.insert(_prof(s), _toks(2, seed=s), 6)
    assert store.n_entries == 2                      # 250 // 100 rows usable
    assert store.bytes_used <= 250
    assert store.evictions == 1


@hypothesis.given(st.lists(st.tuples(st.sampled_from(["ins", "pin", "unpin"]),
                                     st.integers(0, 7)), max_size=60))
def test_store_invariants_under_random_ops(ops):
    """Property: distinct live rows, bytes under budget, pinned entries
    survive any op sequence."""
    store = PrefixStore(n_rows=3, row_bytes=10, n_codebooks=NCB)
    pins = {}
    for op, s in ops:
        if op == "ins":
            store.insert(_prof(s), _toks(2, seed=s), 6)
        else:
            hit = store.lookup_longest(_prof(s), _toks(2, seed=s))
            if hit is None:
                continue
            if op == "pin":
                store.acquire(hit[0])
                pins[hit[0].key] = pins.get(hit[0].key, 0) + 1
            elif pins.get(hit[0].key):
                store.release(hit[0])
                pins[hit[0].key] -= 1
        rows = [e.row for e in store._entries.values()]
        assert len(rows) == len(set(rows))           # no row aliasing
        assert store.bytes_used <= store.max_bytes
        for key, n in pins.items():                  # pinned => still live
            if n:
                assert key in store._entries
                assert store._entries[key].refcount >= n


# ---------------------------------------------------------------------------
# Resume prefill vs full prefill
# ---------------------------------------------------------------------------


def test_attention_resume_fill_matches_full_fill():
    """Filling [0..L) in one shot == filling [0..p) then resuming [p..L):
    identical stored K/V and matching outputs at the suffix positions."""
    spec = AttnSpec(n_heads=4, n_kv_heads=2, head_dim=8)
    params = init_attention(jax.random.PRNGKey(0), 32, spec)
    B, S, L = 3, 16, 12
    lengths = np.array([5, 9, 12])
    starts = np.array([2, 4, 6])
    x = jax.random.normal(jax.random.PRNGKey(1), (B, L, 32), jnp.float32)

    cache = init_cache(B, S, spec, dtype=jnp.float32, per_slot=True)
    out_full, cache_full = apply_attention(
        params, x, spec, positions=jnp.arange(L), cache=cache,
        fill_cache=True, lengths=jnp.asarray(lengths))

    cache = init_cache(B, S, spec, dtype=jnp.float32, per_slot=True)
    _, cache_pre = apply_attention(
        params, x[:, :int(starts.max())], spec,
        positions=jnp.arange(int(starts.max())), cache=cache,
        fill_cache=True, lengths=jnp.asarray(starts))
    suf = lengths - starts
    T = int(suf.max())
    xs = np.zeros((B, T, 32), np.float32)
    for i in range(B):
        xs[i, :suf[i]] = np.asarray(x)[i, starts[i]:lengths[i]]
    out_res, cache_res = apply_attention(
        params, jnp.asarray(xs), spec, cache=cache_pre, fill_cache=True,
        lengths=jnp.asarray(suf), starts=jnp.asarray(starts))

    for i in range(B):
        L_i = lengths[i]
        np.testing.assert_array_equal(
            np.asarray(cache_full["pos"])[i, :L_i],
            np.asarray(cache_res["pos"])[i, :L_i])
        assert (np.asarray(cache_res["pos"])[i, L_i:] == -1).all()
        np.testing.assert_array_equal(            # K/V writes are bit-exact
            np.asarray(cache_full["k"])[i, :L_i],
            np.asarray(cache_res["k"])[i, :L_i])
        np.testing.assert_allclose(               # softmax sizes differ
            np.asarray(out_full)[i, starts[i]:L_i],
            np.asarray(out_res)[i, :suf[i]], rtol=2e-5, atol=2e-6)


def _tiny_cfg() -> OneRecConfig:
    """Capacity-unconstrained MoE so batch composition can't perturb the
    cache-on/off comparison (same reasoning as test_serving_slots)."""
    return OneRecConfig(
        name="onerec-prefix-test",
        history_len=8,
        transformer=TransformerConfig(
            name="onerec-prefix-test-backbone",
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
            d_ff=128, vocab_size=256, moe=True, n_experts=4, top_k=2,
            d_expert=64, capacity_factor=64.0, ep_degree=4,
            max_seq_len=64, remat=False),
        serve_batch=4, beam_width=4)


@pytest.fixture(scope="module")
def prefix_setup():
    cfg = _tiny_cfg()
    params = onerec_model.init_onerec(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_executor_resume_matches_full_prefill(prefix_setup):
    """save -> free -> copy-insert -> resume == one full prefill: the
    next-token logits agree to numerics and the cache rows are identical."""
    cfg, params = prefix_setup
    ex = PhaseExecutor(params, cfg, n_slots=4, use_fp8=True, prefix_rows=2)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 192, size=6 * cfg.n_codebooks).astype(np.int32)
    prof = rng.normal(size=onerec_model.PROFILE_DIM).astype(np.float32)

    logits_full = np.asarray(ex.prefill_insert([toks], [prof], [0]))[0]
    ex.prefix_save([0], [1])
    ex.free_slots([0])
    p = 4 * cfg.n_codebooks                   # resume from the 4-item mark
    ex.prefix_copy_insert([1], [2], [p + 1])
    logits_res = np.asarray(ex.resume_prefill([toks[p:]], [2], [p + 1]))[0]
    np.testing.assert_allclose(logits_res, logits_full, rtol=2e-4, atol=2e-4)
    assert logits_res.argmax() == logits_full.argmax()


def test_free_slots_batch_equals_singles(prefix_setup):
    """One vectorized clear == N single clears, and duplicates are benign."""
    cfg, params = prefix_setup
    rng = np.random.default_rng(1)
    reqs = [(rng.integers(0, 192, size=4 * cfg.n_codebooks).astype(np.int32),
             rng.normal(size=onerec_model.PROFILE_DIM).astype(np.float32))
            for _ in range(3)]
    ex_a = PhaseExecutor(params, cfg, n_slots=4, use_fp8=True)
    ex_b = PhaseExecutor(params, cfg, n_slots=4, use_fp8=True)
    for ex in (ex_a, ex_b):
        ex.prefill_insert([t for t, _ in reqs], [p for _, p in reqs],
                          [0, 1, 2])
    ex_a.free_slots([0, 2, 2])
    ex_b.free_slot(0)
    ex_b.free_slot(2)
    pos_a = jax.tree_util.tree_leaves(ex_a.cache)
    pos_b = jax.tree_util.tree_leaves(ex_b.cache)
    for a, b in zip(pos_a, pos_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _repeat_requests(cfg, n=14, n_users=4, seed=7):
    rng = np.random.default_rng(seed)
    users = [[list(rng.integers(0, 192, size=4 * cfg.n_codebooks)),
              rng.normal(size=onerec_model.PROFILE_DIM).astype(np.float32)]
             for _ in range(n_users)]
    reqs = []
    for i in range(n):
        u = users[i % n_users]
        if i >= n_users:
            u[0] = (u[0] + list(rng.integers(0, 192, size=cfg.n_codebooks))
                    )[-cfg.history_len * cfg.n_codebooks:]
        reqs.append({"tokens": np.asarray(u[0], np.int32),
                     "profile": u[1]})
    return reqs


@pytest.mark.slow
def test_engine_prefix_cache_token_identical(prefix_setup):
    """Cache-on repeat traffic == cache-off, token for token, with a
    nonzero hit rate and saved prefill tokens reported."""
    cfg, params = prefix_setup
    reqs = _repeat_requests(cfg)
    off = ServingEngine(params, cfg, EngineConfig(
        batch_size=4, mode="continuous"))
    on = ServingEngine(params, cfg, EngineConfig(
        batch_size=4, mode="continuous", prefix_cache=True))
    out_off, stats_off = off.serve_requests(reqs)
    out_on, stats_on = on.serve_requests(reqs)
    for a, b in zip(out_on, out_off):
        np.testing.assert_array_equal(a, b)
    assert stats_on["prefix_hit_rate"] > 0.5
    assert stats_on["prefix_tokens_saved"] > 0
    assert stats_on["prefix_bytes_pinned"] > 0
    assert stats_on["prefill_tokens"] < stats_off["prefill_tokens"]
    # store persists across calls: an exact repeat is (near-)all hits via
    # the boundary index, and outputs stay identical
    out2, stats2 = on.serve_requests(reqs)
    for a, b in zip(out2, out_off):
        np.testing.assert_array_equal(a, b)
    assert stats2["prefix_hit_rate"] == 1.0


def test_engine_prefix_cache_requires_continuous(prefix_setup):
    cfg, params = prefix_setup
    with pytest.raises(ValueError):
        ServingEngine(params, cfg, EngineConfig(
            batch_size=4, mode="fixed", prefix_cache=True))
