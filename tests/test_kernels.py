"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import quantize_blockwise, quantize_per_channel
from repro.kernels.batch_attention.ops import batch_attention
from repro.kernels.batch_attention.ref import batch_attention_ref
from repro.kernels.fp8_gemm.ops import fp8_gemm
from repro.kernels.fp8_gemm.ref import fp8_gemm_ref
from repro.kernels.fp8_grouped_gemm.ops import fp8_grouped_gemm
from repro.kernels.fp8_grouped_gemm.ref import fp8_grouped_gemm_ref
from repro.kernels.radix_topk.ops import radix_topk
from repro.kernels.radix_topk.ref import topk_ref


@pytest.mark.parametrize("M,K,N", [(128, 256, 128), (256, 512, 384),
                                   (8, 128, 256), (64, 1024, 128)])
@pytest.mark.parametrize("xdtype", [jnp.bfloat16, jnp.float32])
def test_fp8_gemm_sweep(M, K, N, xdtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (M, K), xdtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
    wq = quantize_per_channel(w)
    out_k = np.asarray(fp8_gemm(x, wq), np.float32)
    out_r = np.asarray(fp8_gemm_ref(x, wq.data, wq.scale.reshape(1, -1)),
                       np.float32)
    np.testing.assert_allclose(out_k, out_r, rtol=2e-2,
                               atol=2e-2 * np.abs(out_r).max())


def test_fp8_gemm_batched_dims():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 128), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 64))
    out = fp8_gemm(x, quantize_per_channel(w))
    assert out.shape == (2, 8, 64)


@pytest.mark.parametrize("E,C,K,N", [(2, 64, 128, 128), (4, 128, 256, 384),
                                     (1, 256, 512, 128)])
def test_fp8_grouped_gemm_sweep(E, C, K, N):
    x = jax.random.normal(jax.random.PRNGKey(0), (E, C, K), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (E, K, N)) * 0.7
    wq = quantize_blockwise(w)
    out_k = np.asarray(fp8_grouped_gemm(x, wq), np.float32)
    out_r = np.asarray(fp8_grouped_gemm_ref(x, wq.data, wq.scale), np.float32)
    np.testing.assert_allclose(out_k, out_r, rtol=2e-2,
                               atol=2e-2 * np.abs(out_r).max())


@pytest.mark.parametrize("B,V,k", [(4, 1024, 8), (8, 4000, 16), (2, 257, 4),
                                   (16, 8192, 64)])
def test_radix_topk_sweep(B, V, k):
    x = jax.random.normal(jax.random.PRNGKey(B + V), (B, V)) * 7
    v1, i1 = radix_topk(x, k)
    v2, i2 = topk_ref(x, k)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=0, atol=0)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_radix_topk_ties_and_negatives():
    x = jnp.array([[5.0, -1.0, 5.0, 5.0, 2.0, -3.0, 2.0, 0.0]])
    v1, i1 = radix_topk(x, 5)
    v2, i2 = topk_ref(x, 5)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    xn = -jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (3, 513)))
    v1, _ = radix_topk(xn, 7)
    v2, _ = topk_ref(xn, 7)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2))


@pytest.mark.parametrize("B,T,H,Kv,hd,S,window", [
    (4, 1, 8, 2, 64, 256, 0),       # GQA decode
    (2, 1, 4, 4, 32, 512, 0),       # MHA decode
    (2, 64, 8, 2, 64, 64, 0),       # short prefill
    (2, 1, 4, 1, 64, 512, 64),      # windowed decode
])
def test_batch_attention_sweep(B, T, H, Kv, hd, S, window):
    q = jax.random.normal(jax.random.PRNGKey(0), (B, T, H, hd), jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Kv, hd), jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Kv, hd), jnp.bfloat16)
    if T == 1:
        q_pos = jnp.full((B, 1), S // 2, jnp.int32)
    else:
        q_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    k_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    out_k = batch_attention(q, k, v, q_pos, k_pos, window=window,
                            block_s=128)
    G = H // Kv
    qr = q.reshape(B, T, Kv, G, hd).transpose(0, 2, 3, 1, 4)
    out_r = batch_attention_ref(qr, k.transpose(0, 2, 1, 3),
                                v.transpose(0, 2, 1, 3), q_pos, k_pos,
                                scale=1 / np.sqrt(hd), window=window)
    out_r = out_r.transpose(0, 3, 1, 2, 4).reshape(B, T, H * hd)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), atol=0.05)


def test_batch_attention_ring_buffer_mask():
    """Empty slots (pos = -1) must not contribute."""
    B, S, Kv, hd = 2, 128, 2, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (B, 1, 4, hd), jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Kv, hd), jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Kv, hd), jnp.bfloat16)
    kp = jnp.where(jnp.arange(S) % 2 == 0, -1, jnp.arange(S)).astype(jnp.int32)
    k_pos = jnp.broadcast_to(kp[None], (B, S))
    q_pos = jnp.full((B, 1), S, jnp.int32)
    out = batch_attention(q, k, v, q_pos, k_pos, block_s=64)
    # zeroing the masked slots must not change the result
    mask = (kp >= 0).astype(k.dtype)[None, :, None, None]
    out2 = batch_attention(q, k * mask, v * mask, q_pos, k_pos, block_s=64)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(out2, np.float32), atol=0.02)
