"""Continuous-batching subsystem: slot allocator, scheduler join/retire vs
the fixed-batch reference, and length-masked decode attention parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OneRecConfig, TransformerConfig
from repro.layers.attention import AttnSpec, apply_attention, init_attention, \
    init_cache
from repro.models import onerec as onerec_model
from repro.serving import EngineConfig, ServingEngine, SlotPool, SlotState


# ---------------------------------------------------------------------------
# Slot allocator
# ---------------------------------------------------------------------------


def _state(rid, length=10):
    return SlotState(request_id=rid, length=length)


def test_slot_pool_alloc_free_exhaustion():
    pool = SlotPool(3)
    slots = [pool.alloc(_state(i)) for i in range(3)]
    assert sorted(slots) == [0, 1, 2]
    assert pool.n_free == 0 and pool.n_used == 3
    assert pool.alloc(_state(99)) is None          # exhausted
    st = pool.free(slots[1])
    assert st.request_id == 1
    assert pool.n_free == 1
    assert pool.alloc(_state(4)) == slots[1]       # slot is reusable
    assert pool.occupancy == 1.0


def test_slot_pool_double_free_raises():
    pool = SlotPool(2)
    s = pool.alloc(_state(0))
    pool.free(s)
    with pytest.raises(KeyError):
        pool.free(s)


def test_slot_pool_lengths_dense_view():
    pool = SlotPool(4)
    s0 = pool.alloc(_state(0, length=7))
    s1 = pool.alloc(_state(1, length=3))
    pool.free(s0)
    lens = pool.lengths(fill=0)
    assert len(lens) == 4
    assert lens[s1] == 3 and lens[s0] == 0


# ---------------------------------------------------------------------------
# Length-masked decode attention vs full-batch reference
# ---------------------------------------------------------------------------


def test_length_masked_decode_matches_lockstep():
    """Per-slot decode at ragged depths == lock-step decode row by row."""
    spec = AttnSpec(n_heads=4, n_kv_heads=2, head_dim=8)
    key = jax.random.PRNGKey(0)
    params = init_attention(key, 32, spec)
    S, B = 16, 3
    lengths = np.array([5, 9, 12])
    prefix = jax.random.normal(jax.random.PRNGKey(1), (B, 12, 32),
                               jnp.float32)
    x_new = jax.random.normal(jax.random.PRNGKey(2), (B, 1, 32), jnp.float32)

    # per-slot path: fill a ragged cache (right-padded prefill), decode once
    cache = init_cache(B, S, spec, dtype=jnp.float32, per_slot=True)
    _, cache = apply_attention(params, prefix, spec,
                               positions=jnp.arange(12), cache=cache,
                               fill_cache=True, lengths=jnp.asarray(lengths))
    out_slot, _ = apply_attention(params, x_new, spec, cache=cache,
                                  lengths=jnp.asarray(lengths))

    # reference: each row alone in a lock-step (shared-pos) cache at its
    # own true length
    for i, L in enumerate(lengths):
        ref_cache = init_cache(1, S, spec, dtype=jnp.float32)
        _, ref_cache = apply_attention(
            params, prefix[i:i + 1, :L], spec, positions=jnp.arange(L),
            cache=ref_cache, fill_cache=True)
        out_ref, _ = apply_attention(
            params, x_new[i:i + 1], spec, positions=jnp.asarray([[L]]),
            cache=ref_cache, cache_index=jnp.int32(L))
        np.testing.assert_allclose(np.asarray(out_slot[i], np.float32),
                                   np.asarray(out_ref[0], np.float32),
                                   rtol=2e-2, atol=2e-3)


def test_per_slot_cache_ignores_padded_positions():
    """K/V written past a row's length must never influence its output."""
    spec = AttnSpec(n_heads=2, n_kv_heads=2, head_dim=8)
    params = init_attention(jax.random.PRNGKey(0), 16, spec)
    B, T, S = 2, 8, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, 16), jnp.float32)
    lengths = jnp.asarray([4, 8])
    cache = init_cache(B, S, spec, dtype=jnp.float32, per_slot=True)
    _, cache_a = apply_attention(params, x, spec, positions=jnp.arange(T),
                                 cache=cache, fill_cache=True,
                                 lengths=lengths)
    # corrupt the padded tail of row 0 before filling: different garbage,
    # same masked result
    x_b = x.at[0, 4:].set(123.0)
    cache = init_cache(B, S, spec, dtype=jnp.float32, per_slot=True)
    _, cache_b = apply_attention(params, x_b, spec, positions=jnp.arange(T),
                                 cache=cache, fill_cache=True,
                                 lengths=lengths)
    x_new = jax.random.normal(jax.random.PRNGKey(2), (B, 1, 16), jnp.float32)
    out_a, _ = apply_attention(params, x_new, spec, cache=cache_a,
                               lengths=lengths)
    out_b, _ = apply_attention(params, x_new, spec, cache=cache_b,
                               lengths=lengths)
    np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_b),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Scheduler join/retire vs fixed-batch reference
# ---------------------------------------------------------------------------


def _tiny_cfg() -> OneRecConfig:
    """Small OneRec with capacity-unconstrained MoE: batch composition must
    not change outputs (capacity drops depend on batchmates), so the
    continuous-vs-fixed comparison is exact token-for-token."""
    return OneRecConfig(
        name="onerec-slots-test",
        history_len=8,
        transformer=TransformerConfig(
            name="onerec-slots-test-backbone",
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
            d_ff=128, vocab_size=256, moe=True, n_experts=4, top_k=2,
            d_expert=64, capacity_factor=64.0, ep_degree=4,
            max_seq_len=64, remat=False),
        serve_batch=4, beam_width=4)


@pytest.fixture(scope="module")
def slot_setup():
    cfg = _tiny_cfg()
    params = onerec_model.init_onerec(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    reqs = []
    for _ in range(11):                      # non-multiple of batch 4
        n_items = int(rng.integers(2, cfg.history_len + 1))
        reqs.append({
            "tokens": rng.integers(0, 192, size=n_items * cfg.n_codebooks
                                   ).astype(np.int32),
            "profile": rng.normal(size=onerec_model.PROFILE_DIM
                                  ).astype(np.float32)})
    return cfg, params, reqs


@pytest.mark.slow
def test_continuous_matches_fixed_reference(slot_setup):
    cfg, params, reqs = slot_setup
    out_f, st_f = ServingEngine(params, cfg, EngineConfig(
        batch_size=4, mode="fixed")).serve_requests(reqs)
    out_c, st_c = ServingEngine(params, cfg, EngineConfig(
        batch_size=4, mode="continuous")).serve_requests(reqs)
    assert len(out_c) == len(reqs)
    for a, b in zip(out_c, out_f):
        np.testing.assert_array_equal(a, b)
    assert st_c["slot_occupancy"] > 0
    assert st_c["mode"] == "continuous" and st_f["mode"] == "fixed"


def test_continuous_more_slots_than_batch(slot_setup):
    """A bigger slot pool must not change results, only the schedule."""
    cfg, params, reqs = slot_setup
    base, _ = ServingEngine(params, cfg, EngineConfig(
        batch_size=4, mode="fixed")).serve_requests(reqs)
    wide, stats = ServingEngine(params, cfg, EngineConfig(
        batch_size=4, n_slots=8, mode="continuous")).serve_requests(reqs)
    for a, b in zip(wide, base):
        np.testing.assert_array_equal(a, b)
    assert stats["n_slots"] == 8.0


def test_metrics_windowed_per_call(slot_setup):
    """Seed bug: latencies accumulated across serve_requests calls,
    contaminating the second call's mean/p99."""
    cfg, params, reqs = slot_setup
    eng = ServingEngine(params, cfg, EngineConfig(batch_size=4))
    eng.serve_requests(reqs)                  # includes jit compiles (slow)
    n_first = len(eng.metrics["latency_s"])
    assert n_first == len(reqs)
    _, stats = eng.serve_requests(reqs[:5])   # warm (fast)
    assert len(eng.metrics["latency_s"]) == 5  # windowed, not accumulated
    assert stats["n_requests"] == 5.0
    # warm per-request latencies can't exceed the cold call's slowest
    assert max(eng.metrics["latency_s"]) <= n_first * 100  # sanity bound


def test_staggered_arrivals_honored(slot_setup):
    """A request with a future ``arrival_s`` offset must not be admitted
    early, and its latency must be measured from ITS arrival (review
    regression: early admission back-dated latencies, even negative)."""
    cfg, params, reqs = slot_setup
    eng = ServingEngine(params, cfg, EngineConfig(batch_size=4))
    eng.serve_requests(reqs[:4])              # warm the compile caches
    staggered = [dict(reqs[0]), dict(reqs[1], arrival_s=0.5)]
    _, stats = eng.serve_requests(staggered)
    lat = eng.metrics["latency_s"]
    assert all(l > 0 for l in lat)
    # the late request was served after it arrived, not batched up front
    assert stats["wall_s"] >= 0.5


@pytest.mark.slow
def test_uniform_lengths_still_work(slot_setup):
    """Degenerate case: all histories equal (the seed engine's workload)."""
    cfg, params, _ = slot_setup
    rng = np.random.default_rng(3)
    reqs = [{"tokens": rng.integers(0, 192, size=cfg.history_len *
                                    cfg.n_codebooks).astype(np.int32),
             "profile": rng.normal(size=onerec_model.PROFILE_DIM
                                   ).astype(np.float32)}
            for _ in range(6)]
    out_c, _ = ServingEngine(params, cfg, EngineConfig(
        batch_size=4, mode="continuous")).serve_requests(reqs)
    out_f, _ = ServingEngine(params, cfg, EngineConfig(
        batch_size=4, mode="fixed")).serve_requests(reqs)
    for a, b in zip(out_c, out_f):
        np.testing.assert_array_equal(a, b)
    assert all(o.shape == (cfg.decode_len,) for o in out_c)
