"""Beyond-paper extensions: beam search, INT8 frontier, fused decode."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.core.policy import PAPER_POLICY
from repro.core.ptq import quantize_params
from repro.core.quant import (int8_linear, quantize_per_channel,
                              quantize_per_channel_int8)
from repro.models import onerec as om
from repro.models import transformer as tfm


def _setup():
    cfg = get_arch("onerec-v2").reduced_config()
    params = om.init_onerec(jax.random.PRNGKey(0), cfg)
    T = cfg.history_len * 3
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, T), 0,
                                          cfg.vocab_size),
             "profile": jax.random.normal(jax.random.PRNGKey(2),
                                          (2, om.PROFILE_DIM))}
    return cfg, params, batch


def test_beam_width_1_equals_greedy():
    cfg, params, batch = _setup()
    greedy = om.generate_items(params, batch, cfg)
    beams, scores = om.beam_generate(params, batch, cfg, beam_width=1)
    np.testing.assert_array_equal(np.asarray(beams[:, 0, :]),
                                  np.asarray(greedy))


def test_beam_search_monotone_and_sorted():
    cfg, params, batch = _setup()
    _, s1 = om.beam_generate(params, batch, cfg, beam_width=1)
    beams4, s4 = om.beam_generate(params, batch, cfg, beam_width=4)
    assert beams4.shape == (2, 4, cfg.decode_len)
    # wider beams can only improve the best score; scores sorted desc
    assert np.all(np.asarray(s4[:, 0]) >= np.asarray(s1[:, 0]) - 1e-4)
    assert np.all(np.diff(np.asarray(s4), axis=1) <= 1e-6)


def test_int8_linear_more_accurate_than_fp8_on_gaussians():
    """Same bytes/param: int8 (7 mantissa bits, per-channel symmetric) beats
    e4m3 on outlier-free weights; fp8's advantage is dynamic range
    (test_quant.test_block_outlier_isolation covers that side)."""
    w = jax.random.normal(jax.random.PRNGKey(0), (128, 256))
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 128), jnp.bfloat16)
    ref = np.asarray(x, np.float32) @ np.asarray(w)
    out8 = np.asarray(int8_linear(x, quantize_per_channel_int8(w)),
                      np.float32)
    from repro.core.quant import fp8_linear
    outf = np.asarray(fp8_linear(x, quantize_per_channel(w)), np.float32)
    err8 = np.linalg.norm(out8 - ref) / np.linalg.norm(ref)
    errf = np.linalg.norm(outf - ref) / np.linalg.norm(ref)
    assert err8 < errf < 0.06


def test_int8_policy_end_to_end():
    cfg, params, batch = _setup()
    qp, rep = quantize_params(params, PAPER_POLICY.replace(fmt="int8"),
                              with_report=True, compute_errors=True)
    assert rep.mean_rel_err < 0.01
    lg_bf, _ = om.forward(params, batch, cfg)
    lg_i8, _ = om.forward(qp, batch, cfg)
    a = np.asarray(lg_bf, np.float32).ravel()
    b = np.asarray(lg_i8, np.float32).ravel()
    assert a @ b / (np.linalg.norm(a) * np.linalg.norm(b)) > 0.995


def test_decode_fused_matches_stepwise():
    cfg, params, batch = _setup()
    tcfg = cfg.transformer
    bp = params["backbone"]
    cache = om.init_cache(cfg, 2)
    logits, cache = om.prefill(params, batch, cfg, cache)
    first = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    idx = jnp.int32(batch["tokens"].shape[1] + 1)

    toks_fused, _ = tfm.decode_fused(bp, first, tcfg, cache, idx, 3)

    toks_step = [first]
    c = cache
    i = idx
    for _ in range(2):
        lg, c = tfm.decode_step(bp, toks_step[-1], tcfg, c, i)
        toks_step.append(jnp.argmax(lg, -1)[:, None].astype(jnp.int32))
        i = i + 1
    toks_step = jnp.concatenate(toks_step, axis=1)
    np.testing.assert_array_equal(np.asarray(toks_fused),
                                  np.asarray(toks_step))
