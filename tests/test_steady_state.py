"""Runtime steady-state guards: the post-warmup serving contract.

The paper's serving wins assume the hot path is compile-free after
warmup: every shape a steady-state step can produce was already compiled
(the pow-2 bucket lattice), and no value crosses host<->device
implicitly.  These tests prove the guards measure exactly that — the
warmed engine (paged + fp8 KV + fused interpret decode) steps ≥8 times
with ZERO new XLA compilations and zero implicit transfers — and that
the guard actually TRIPS on each injected violation class.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.guards import (CompileMonitor, SteadyStateViolation,
                                   steady_state, warmup_then_guard)
from repro.configs.base import OneRecConfig, TransformerConfig
from repro.models import onerec as onerec_model
from repro.serving import EngineConfig, ServingEngine
from repro.serving.requests import make_request

SEED = 31
PAGE = 8


def _cfg() -> OneRecConfig:
    return OneRecConfig(
        name="onerec-steady-test",
        history_len=8,
        transformer=TransformerConfig(
            name="onerec-steady-test-backbone",
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
            d_ff=128, vocab_size=256, moe=True, n_experts=4, top_k=2,
            d_expert=64, capacity_factor=64.0, ep_degree=4,
            max_seq_len=64, remat=False),
        serve_batch=4, beam_width=4)


@pytest.fixture(scope="module")
def warmed_engine():
    """The full serving feature stack — paged pool + fp8 KV storage +
    fused interpret decode — warmed on the exact request list the steady
    phase will replay (identical batch composition -> identical bucket
    shapes)."""
    cfg = _cfg()
    params = onerec_model.init_onerec(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(SEED)
    reqs = []
    for _ in range(12):
        n_items = int(rng.integers(2, cfg.history_len + 1))
        reqs.append(make_request(
            rng.integers(0, 192, size=n_items * cfg.n_codebooks),
            rng.normal(size=onerec_model.PROFILE_DIM)))
    engine = ServingEngine(params, cfg, EngineConfig(
        batch_size=4, n_slots=3, mode="continuous", use_fp8=False,
        kv_dtype="float8_e4m3fn", paged=True, page_size=PAGE,
        fused_decode="interpret"))
    warm_out, _ = engine.serve_requests(reqs)     # all compiles land here
    return engine, reqs, warm_out


# -- the steady-state contract ------------------------------------------------

def test_steady_engine_steps_compile_and_transfer_free(warmed_engine):
    """≥8 post-warmup decode steps: zero new compilations, zero implicit
    transfers, and the outputs still match the warmup pass."""
    engine, reqs, warm_out = warmed_engine
    with engine.steady_state() as mon:
        out, stats = engine.serve_requests(reqs)
    assert stats["decode_steps"] >= 8
    assert stats["fused_decode_steps"] == stats["decode_steps"]
    assert mon.compiles == 0
    for a, b in zip(out, warm_out):
        np.testing.assert_array_equal(a, b)


def test_guard_trips_on_unbucketed_shape(warmed_engine):
    """A deliberately unbucketed dispatch — a shape no warmup step ever
    produced — must compile, and the guard must turn that into a loud
    SteadyStateViolation."""
    engine, reqs, _ = warmed_engine
    odd = jnp.zeros((5, 37), jnp.float32)         # 5 and 37 are no buckets
    with pytest.raises(SteadyStateViolation, match="compilation"):
        with engine.steady_state() as mon:
            engine.executor._select(odd)
    assert mon.compiles >= 1


def test_guard_trips_on_implicit_transfer(warmed_engine):
    """A raw numpy operand flowing into a jitted program is an IMPLICIT
    host->device transfer and must raise immediately under the guard
    (the engine's own jnp.asarray staging is explicit and sanctioned)."""
    engine, _, _ = warmed_engine
    vocab = engine.cfg.transformer.vocab_size
    host_logits = np.zeros((4, vocab), np.float32)
    jax.block_until_ready(
        engine.executor._select(jnp.asarray(host_logits)))  # warmed shape
    with pytest.raises(Exception, match="[Dd]isallow"):
        with engine.steady_state():
            engine.executor._select(host_logits)


# -- guard unit behavior (no engine) ------------------------------------------

def test_compile_monitor_counts_fresh_compiles_only():
    @jax.jit
    def f(x):
        return x * 2 + 1

    jax.block_until_ready(f(jnp.ones((4,))))      # warm
    with CompileMonitor() as mon:
        jax.block_until_ready(f(jnp.ones((4,))))  # cache hit
    assert mon.compiles == 0
    with CompileMonitor() as mon:
        jax.block_until_ready(f(jnp.ones((6,))))  # fresh shape
    assert mon.compiles >= 1
    assert mon.traces >= 1


def test_nested_monitors_count_independently():
    @jax.jit
    def g(x):
        return x - 1

    with CompileMonitor() as outer:
        jax.block_until_ready(g(jnp.ones((3,))))
        with CompileMonitor() as inner:
            jax.block_until_ready(g(jnp.ones((3,))))   # warmed above
    assert outer.compiles >= 1
    assert inner.compiles == 0


def test_steady_state_max_compiles_budget():
    @jax.jit
    def h(x):
        return x + 3

    # operands built OUTSIDE the guard (jnp.ones compiles a program of
    # its own); allow_transfers because a fresh compile stages scalar
    # constants, which the transfer guard would flag before the budget
    # check ever runs
    x7, x9 = jnp.ones((7,)), jnp.ones((9,))
    with steady_state(allow_transfers=True, max_compiles=1) as mon:
        jax.block_until_ready(h(x7))
    assert mon.compiles == 1
    with pytest.raises(SteadyStateViolation):
        with steady_state(allow_transfers=True):
            jax.block_until_ready(h(x9))


def test_steady_state_does_not_mask_inner_exception():
    @jax.jit
    def m(x):
        return x + 1

    x = jnp.ones((11,))
    with pytest.raises(ValueError, match="inner"):
        with steady_state(allow_transfers=True):
            jax.block_until_ready(m(x))   # compiles — but the user error
            raise ValueError("inner")     # must win over the violation


def test_warmup_then_guard():
    @jax.jit
    def k(x):
        return x * x

    x = jnp.ones((5,))
    with warmup_then_guard(lambda: jax.block_until_ready(k(x))) as mon:
        jax.block_until_ready(k(x))
    assert mon.compiles == 0
